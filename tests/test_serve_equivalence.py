"""Pipelined-vs-synchronous equivalence of the packed serving hot loop.

The double-buffered pipeline (``pipeline=True``) overlaps host bookkeeping
for step t+1 with the device computing step t, and ``telemetry_every=k``
defers the governor/ledger/stats replay to flush boundaries.  Neither is
allowed to change WHAT is served: for a fixed seed and a fixed submission
schedule, every mode must produce identical labels, hops, shed sets,
governor transitions and registry version pinning — the pipeline moves
work in wall time, never in step time.

Everything runs in ONE process: ``make_dataset`` is process-seeded, so
cross-process runs see different data, but within a process each mode
rebuilds an identical plane from the same seed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FogPolicy, split
from repro.core.engine import splice_lanes, splice_slot_state
from repro.core.policy import (BUDGET_DEFAULT, DEAD_BUDGET, DEAD_THRESH,
                               LanePolicies, THRESH_DEFAULT)
from repro.forest import ForestPack
from repro.launch.mesh import serve_devices
from repro.registry import ModelRegistry, PackCache
from repro.serve.dispatch import DeviceDispatcher, ForestReplicaServer
from repro.serve.governor import EnergyGovernor, default_ladder
from repro.serve.scheduler import ContinuousBatcher, Request


# --------------------------------------------------------------------------
# splice primitives
# --------------------------------------------------------------------------

def test_splice_copy_matches_donating_and_preserves_source():
    """donate=False must compute the same buffer as donate=True while
    leaving the source readable (the pipeline's previous dispatch may
    still hold it)."""
    base = np.arange(24, dtype=np.float32).reshape(8, 3)
    idx = [1, 4, 6]
    vals = -np.ones((3, 3), np.float32)
    donated = splice_lanes(jnp.asarray(base), idx, vals, donate=True)
    src = jnp.asarray(base)
    copied = splice_lanes(src, idx, vals, donate=False)
    np.testing.assert_array_equal(np.asarray(donated), np.asarray(copied))
    # the copying splice left its source untouched and alive
    np.testing.assert_array_equal(np.asarray(src), base)
    want = base.copy()
    want[idx] = -1.0
    np.testing.assert_array_equal(np.asarray(copied), want)


def test_splice_slot_state_matches_three_single_splices():
    """The fused three-buffer splice is exactly three splice_lanes calls
    sharing one index set (any burst width, pow-2 padding included)."""
    rng = np.random.default_rng(0)
    n, f = 16, 5
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    thr = jnp.asarray(rng.random(n).astype(np.float32))
    bud = jnp.asarray(rng.integers(0, 9, n).astype(np.int32))
    for width in (1, 3, 8, 16):
        idx = np.sort(rng.choice(n, size=width, replace=False))
        rows = rng.normal(size=(width, f)).astype(np.float32)
        t = rng.random(width).astype(np.float32)
        b = rng.integers(0, 9, width).astype(np.int32)
        fx, fthr, fbud = splice_slot_state(x, thr, bud, idx, rows, t, b,
                                           donate=False)
        np.testing.assert_array_equal(
            np.asarray(fx),
            np.asarray(splice_lanes(x, idx, rows, donate=False)))
        np.testing.assert_array_equal(
            np.asarray(fthr),
            np.asarray(splice_lanes(thr, idx, t, donate=False)))
        np.testing.assert_array_equal(
            np.asarray(fbud),
            np.asarray(splice_lanes(bud, idx, b, donate=False)))


def test_lane_policies_dirty_tracking_round_trip():
    lp = LanePolicies(6)
    assert not lp.dirty
    lp.stamp_many(np.asarray([4, 1]), np.float32(0.5), np.int32(3))
    lp.retire_many(np.asarray([2]))
    assert lp.dirty
    idx, thr, bud = lp.take_dirty()
    np.testing.assert_array_equal(idx, [1, 2, 4])   # ascending, clears
    np.testing.assert_array_equal(thr, np.float32([0.5, DEAD_THRESH, 0.5]))
    np.testing.assert_array_equal(bud, np.int32([3, DEAD_BUDGET, 3]))
    assert not lp.dirty
    # sentinels resolve against the step default, concrete stamps win
    lp.stamp(0, THRESH_DEFAULT, BUDGET_DEFAULT)
    rthr, rbud = lp.resolve(FogPolicy(threshold=0.9, hop_budget=7))
    assert rthr[0] == np.float32(0.9) and rbud[0] == 7
    assert rthr[1] == np.float32(0.5) and rbud[1] == 3


# --------------------------------------------------------------------------
# closed-loop mode equivalence
# --------------------------------------------------------------------------

N_SLOTS = 8


def _run_mode(trained, *, pipeline, telemetry_every, governor_budget="none",
              max_queue=None, waves=6, wave_n=16, steps_per_wave=3):
    """One full serving run at a fixed submission schedule: ``waves``
    bursts of ``wave_n`` requests with ``steps_per_wave`` steps between
    bursts, then drain.  Fresh plane + governor per call, same seed."""
    ds, rf = trained
    gc = split(rf, 2)
    server = ForestReplicaServer(gc, ds.x_test.shape[1], backend="fused",
                                 precisions=("fp32", "int8"), seed=0)
    disp = DeviceDispatcher(server.packed_factory, serve_devices(1))
    base = FogPolicy(threshold=0.7, precision="fp32")
    gov = None
    if governor_budget != "none":
        model = server.energy_model("fp32")
        ladder = default_ladder(base, model, governor_budget)
        gov = EnergyGovernor(ladder, governor_budget, model=model,
                             window=16, patience=2, cooldown=10_000,
                             warmup=4)
    b = ContinuousBatcher(N_SLOTS, None, server.prefill, eos_id=-1,
                          default_policy=base, governor=gov,
                          dispatcher=disp, max_queue=max_queue,
                          pipeline=pipeline,
                          telemetry_every=telemetry_every)
    rid = 0
    for _ in range(waves):
        for _ in range(wave_n):
            pol = (FogPolicy(threshold=0.55, precision="int8")
                   if rid % 3 == 0 else None)
            b.submit(Request(rid=rid, prompt=ds.x_test[rid % len(ds.x_test)],
                             max_new_tokens=1, policy=pol,
                             tier="bulk" if rid % 3 == 0 else "std"))
            rid += 1
        for _ in range(steps_per_wave):
            b.step()
    while b.active or b.queue:
        b.step()
    b.flush()
    return b, gov


def _served(b):
    return {r.rid: (tuple(r.generated), tuple(r.hops))
            for r in b.completed}


def test_pipelined_step_is_bit_equal_to_synchronous(trained):
    """pipeline=True with per-step telemetry serves exactly what the
    synchronous step serves: same labels, same hops, same shed set, same
    fleet stats — under queue pressure and a mixed-precision bucket mix."""
    sync, _ = _run_mode(trained, pipeline=False, telemetry_every=1,
                        max_queue=24)
    pipe, _ = _run_mode(trained, pipeline=True, telemetry_every=1,
                        max_queue=24)
    assert _served(sync) == _served(pipe)
    assert ({r.rid for r in sync.shed_requests}
            == {r.rid for r in pipe.shed_requests})
    for attr in ("total_hops", "n_events", "n_offered", "n_shed"):
        assert getattr(sync.stats, attr) == getattr(pipe.stats, attr)
    assert sync.stats.tier_summary() == pipe.stats.tier_summary()


def test_pipelined_governor_transitions_match_synchronous(trained):
    """A TIGHT energy SLO walks the ladder mid-run; the pipeline (which
    harvests one step late) must reproduce the synchronous governor's
    transition sequence and final rung exactly — telemetry is replayed by
    harvest index, not by wall order."""
    ds, rf = trained
    gc = split(rf, 2)
    server = ForestReplicaServer(gc, ds.x_test.shape[1], backend="fused",
                                 precisions=("fp32", "int8"), seed=0)
    model = server.energy_model("fp32")
    # budget around the cost of ~1.5 hops: the base rung breaches, the
    # ladder walks — both modes must agree on every step of that walk
    budget = float(np.asarray(model.lane_pj(np.asarray([2]))[0])) * 1e-3 * 0.8
    sync, gov_s = _run_mode(trained, pipeline=False, telemetry_every=1,
                            governor_budget=budget)
    pipe, gov_p = _run_mode(trained, pipeline=True, telemetry_every=1,
                            governor_budget=budget)
    assert _served(sync) == _served(pipe)
    assert gov_s.transitions == gov_p.transitions
    assert len(gov_s.transitions) >= 1      # the SLO actually bit
    assert gov_s.rung == gov_p.rung
    assert gov_s.rolling_nj == pytest.approx(gov_p.rolling_nj)


def test_deferred_telemetry_changes_when_not_what(trained):
    """telemetry_every=8 batches the replay but, with a metering-only
    governor (no stepping), must leave every post-flush observable equal
    to the per-step account: labels, stats totals, rolling estimate."""
    ref, gov_r = _run_mode(trained, pipeline=False, telemetry_every=1,
                           governor_budget=None)
    defer, gov_d = _run_mode(trained, pipeline=True, telemetry_every=8,
                             governor_budget=None)
    assert _served(ref) == _served(defer)
    assert ref.stats.total_hops == defer.stats.total_hops
    assert ref.stats.n_events == defer.stats.n_events
    assert ref.stats.total_pj == pytest.approx(defer.stats.total_pj)
    assert gov_r.rolling_nj == pytest.approx(gov_d.rolling_nj)
    assert gov_r.transitions == gov_d.transitions == []


def _run_swap(trained, tmp_path, *, pipeline, telemetry_every):
    """Registry-mode serving with a mid-run hot-swap at a fixed step
    boundary: 2 full steps on v1 traffic, publish v2, second burst,
    drain.  Version pinning happens at slot assignment, so both modes
    must pin the same rid -> version map."""
    ds, rf = trained
    pack = ForestPack.from_groves(split(rf, 2))
    reg = ModelRegistry(tmp_path / f"reg-{pipeline}-{telemetry_every}")
    reg.publish("t", pack)
    cache = PackCache(reg, budget_bytes=4 * pack.table_bytes)
    server = ForestReplicaServer(None, ds.x_test.shape[1], backend="fused",
                                 registry=reg, cache=cache, seed=0)
    disp = DeviceDispatcher(server.packed_factory, serve_devices(1))
    b = ContinuousBatcher(4, None, server.prefill, eos_id=-1,
                          default_policy=FogPolicy(threshold=0.7,
                                                   precision="fp32"),
                          dispatcher=disp, registry=reg, pipeline=pipeline,
                          telemetry_every=telemetry_every)
    for rid in range(8):
        b.submit(Request(rid=rid, prompt=ds.x_test[rid], max_new_tokens=1,
                         model="t"))
    for _ in range(2):
        b.step()
    reg.publish("t", pack)                  # hot-swap mid-flight
    for rid in range(8, 16):
        b.submit(Request(rid=rid, prompt=ds.x_test[rid], max_new_tokens=1,
                         model="t"))
    while b.active or b.queue:
        b.step()
    b.flush()
    return {r.rid: (r.version, tuple(r.generated), tuple(r.hops))
            for r in b.completed}


def test_hot_swap_version_pinning_matches_across_modes(trained, tmp_path):
    sync = _run_swap(trained, tmp_path, pipeline=False, telemetry_every=1)
    pipe = _run_swap(trained, tmp_path, pipeline=True, telemetry_every=4)
    assert sync == pipe
    assert len(sync) == 16
    versions = {v for v, _, _ in sync.values()}
    assert versions == {1, 2}               # the swap actually happened
    # requests in flight (or queued) before the publish stayed on v1
    assert all(sync[rid][0] == 1 for rid in range(8))
    assert all(sync[rid][0] == 2 for rid in range(8, 16))


def test_flush_is_idempotent_and_drains_inflight(trained):
    """flush() mid-run harvests the in-flight dispatch and replays the
    buffered telemetry; a second flush is a no-op."""
    ds, rf = trained
    gc = split(rf, 2)
    server = ForestReplicaServer(gc, ds.x_test.shape[1], backend="fused",
                                 precisions=("fp32",), seed=0)
    disp = DeviceDispatcher(server.packed_factory, serve_devices(1))
    b = ContinuousBatcher(4, None, server.prefill, eos_id=-1,
                          default_policy=FogPolicy(threshold=0.7,
                                                   precision="fp32"),
                          dispatcher=disp, pipeline=True, telemetry_every=16)
    for rid in range(4):
        b.submit(Request(rid=rid, prompt=ds.x_test[rid], max_new_tokens=1))
    b.step()                                # dispatched, nothing harvested
    assert len(b.completed) == 0
    b.flush()
    assert len(b.completed) == 4            # in-flight drained
    assert b.stats.n_events == 4            # telemetry replayed
    before = (b.stats.n_events, b.stats.total_hops, len(b.completed))
    b.flush()
    assert (b.stats.n_events, b.stats.total_hops, len(b.completed)) == before
