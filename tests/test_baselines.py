"""Baseline classifiers: sanity accuracy + Table-1 orderings on one dataset."""
import numpy as np
import pytest

from repro.baselines import train_cnn, train_mlp, train_svm_lr, train_svm_rbf
from repro.data import make_dataset
from repro.forest import TrainConfig, rf_predict, train_random_forest


@pytest.fixture(scope="module")
def seg():
    return make_dataset("segmentation")


@pytest.fixture(scope="module")
def models(seg):
    return {
        "svm_lr": train_svm_lr(seg),
        "svm_rbf": train_svm_rbf(seg),
        "mlp": train_mlp(seg),
        "cnn": train_cnn(seg),
    }


def test_baselines_learn(models):
    for name, m in models.items():
        assert m.accuracy > 0.5, (name, m.accuracy)


def test_nonlinear_beats_linear(models):
    """Table 1's central ordering: RBF/MLP/CNN > linear SVM on these tasks."""
    assert models["svm_rbf"].accuracy > models["svm_lr"].accuracy + 0.05
    assert models["mlp"].accuracy > models["svm_lr"].accuracy


def test_rf_competitive_with_nonlinear(seg, models):
    rf = train_random_forest(seg.x_train, seg.y_train, seg.n_classes,
                             TrainConfig(n_trees=16, max_depth=8, seed=0))
    import jax.numpy as jnp
    acc = float(np.mean(np.asarray(rf_predict(rf, jnp.asarray(seg.x_test))) == seg.y_test))
    assert acc > models["svm_lr"].accuracy
    assert acc > models["svm_rbf"].accuracy - 0.06


def test_energy_ordering(models):
    """Table 1 energies: SVM_LR cheapest; CNN and RBF the most expensive."""
    e = {k: m.energy_nj for k, m in models.items()}
    assert e["svm_lr"] < e["mlp"] < e["cnn"]
    assert e["svm_rbf"] > e["svm_lr"] * 5
