"""Shared fixtures: expensive trained-forest artifacts are session-scoped so
the whole suite trains each forest exactly once."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def ds_penbased():
    from repro.data import make_dataset
    return make_dataset("penbased")


@pytest.fixture(scope="session")
def rf16_penbased(ds_penbased):
    """16-tree depth-6 forest on penbased — the workhorse FoG fixture."""
    from repro.forest import TrainConfig, train_random_forest
    return train_random_forest(
        ds_penbased.x_train, ds_penbased.y_train, ds_penbased.n_classes,
        TrainConfig(n_trees=16, max_depth=6, seed=1))


@pytest.fixture(scope="session")
def trained(ds_penbased, rf16_penbased):
    """(dataset, forest) pair used across fog-core and engine tests."""
    return ds_penbased, rf16_penbased


@pytest.fixture(scope="session")
def rf8_penbased(ds_penbased):
    """8-tree clean-label forest (the easy multi-output head)."""
    from repro.forest import TrainConfig, train_random_forest
    return train_random_forest(
        ds_penbased.x_train, ds_penbased.y_train, ds_penbased.n_classes,
        TrainConfig(n_trees=8, max_depth=6, seed=1))


@pytest.fixture(scope="session")
def rf8_noisy_penbased(ds_penbased):
    """Forest trained on 45%-noised labels — the hard multi-output head."""
    from repro.forest import TrainConfig, train_random_forest
    ds = ds_penbased
    rng = np.random.default_rng(0)
    y2 = np.where(rng.random(len(ds.y_train)) < 0.45,
                  rng.integers(0, ds.n_classes, len(ds.y_train)), ds.y_train)
    return train_random_forest(ds.x_train, y2.astype(np.int32), ds.n_classes,
                               TrainConfig(n_trees=8, max_depth=6, seed=2))
