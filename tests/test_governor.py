"""EnergyGovernor (serve/governor.py) driving a REAL FogEngine loop: the
serving control plane must step down the calibrated ladder when the rolling
nJ estimate breaches the SLO, settle on a compliant rung, and keep
``EvalReport.energy_pj`` under budget in steady state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnergyModel, FogEngine, FogPolicy, build_frontier, split
from repro.serve.governor import EnergyGovernor, default_ladder


@pytest.fixture(scope="module")
def engine(trained):
    _, rf = trained
    return FogEngine(split(rf, 2))


@pytest.fixture(scope="module")
def xy(trained):
    ds, _ = trained
    return ds.x_test[:256], ds.y_test[:256]


def _rung_cost_nj(engine, x, policy):
    res = engine.eval(jnp.asarray(x), jax.random.key(0), policy=policy)
    return res.energy_report().per_example_nj


def test_default_ladder_rung_order():
    """The ISSUE's ladder: tighten threshold -> drop to int8 -> cut hops."""
    model = EnergyModel(2, 8, 10, 16)
    base = FogPolicy(threshold=0.6)
    ladder = default_ladder(base, model, budget_nj=0.5)
    assert len(ladder) == 4
    assert ladder[0] == base
    assert ladder[1].threshold == pytest.approx(0.3)
    assert ladder[1].precision is None
    assert ladder[2].precision == "int8" and ladder[2].hop_budget is None
    assert ladder[3].precision == "int8"
    assert ladder[3].hop_budget == model.hops_within(500.0)


def test_observe_requires_model_or_energy():
    gov = EnergyGovernor([FogPolicy()], budget_nj=1.0)
    with pytest.raises(ValueError, match="hops or energy_pj"):
        gov.observe()
    with pytest.raises(ValueError, match="energy model"):
        gov.observe(hops=np.ones(4))
    gov.observe(energy_pj=np.full(4, 500.0))
    assert gov.rolling_nj == pytest.approx(0.5)


def test_hops_priced_at_active_rung_precision():
    """Stepping down to an int8 rung must show a measured saving even for
    identical hop counts — pricing follows the ACTIVE rung's precision."""
    model = EnergyModel(2, 8, 10, 16)
    fp32 = FogPolicy(threshold=0.4)
    int8 = FogPolicy(threshold=0.4, precision="int8")
    gov = EnergyGovernor([fp32, int8], budget_nj=None, model=model)
    hops = np.full(16, 3)
    at_fp32 = gov.price(hops).mean()
    gov.rung = 1
    at_int8 = gov.price(hops).mean()
    assert at_int8 < at_fp32
    assert at_int8 == pytest.approx(float(np.asarray(
        EnergyModel(2, 8, 10, 16, "int8").lane_pj(hops)).mean()))


def test_rolling_estimate_resets_on_transition():
    """The EWMA estimates the CURRENT rung's cost: carrying it across a
    step-down would blame the new rung for the old rung's spending and
    cascade one expensive burst down the whole ladder."""
    model = EnergyModel(2, 8, 10, 16)
    gov = EnergyGovernor([FogPolicy(threshold=0.9), FogPolicy(threshold=0.4),
                          FogPolicy(threshold=0.1)],
                         budget_nj=0.5, model=model, window=256, warmup=16)
    gov.observe(hops=np.full(16, 8))     # one expensive burst on rung 0
    gov.step()
    assert gov.rung == 1
    assert gov.rolling_nj is None        # fresh estimate for the new rung
    # the warmup guards the fresh rung: a single-sample outlier right
    # after the transition must neither act (too little evidence) nor
    # outweigh the representative batch that follows (sample-weighted
    # warm phase), so compliant traffic does NOT cascade another step-down
    gov.observe(hops=np.asarray([16]))
    gov.step()
    assert gov.rung == 1                 # 1 sample < warmup: no action
    gov.observe(hops=np.ones(32, np.int64))
    gov.step()
    assert gov.rung == 1                 # true mean under budget: no move
    assert gov.rolling_nj <= gov.budget_nj
    assert 1 not in {a for a, _, _ in gov.transitions[1:]}


def test_per_lane_rung_rejected():
    with pytest.raises(ValueError, match="per-lane"):
        EnergyGovernor([FogPolicy(threshold=jnp.asarray([0.1, 0.2]))],
                       budget_nj=1.0)


def test_governor_steps_fp32_to_int8_and_holds_budget(engine, xy):
    """The acceptance loop on a real engine: budget sits between the fp32
    and int8 rungs' true costs, so the governor must walk base -> tightened
    -> int8 and then hold EvalReport.energy_pj under budget in steady
    state."""
    x, _ = xy
    base = FogPolicy(threshold=0.9)
    tight = FogPolicy(threshold=0.45)
    int8 = FogPolicy(threshold=0.45, precision="int8")
    cost = {p: _rung_cost_nj(engine, x, p) for p in (base, tight, int8)}
    assert cost[int8] < cost[tight] < cost[base]     # ladder really descends
    # an SLO only the int8 rung can meet
    budget = (cost[int8] + cost[tight]) / 2
    gov = EnergyGovernor([base, tight, int8], budget_nj=budget,
                         model=engine.energy_model("fp32"),
                         window=len(x), patience=3, cooldown=10_000)
    for i in range(8):
        res = engine.eval(jnp.asarray(x), jax.random.key(i),
                          policy=gov.current)
        gov.observe(energy_pj=np.asarray(res.energy_pj))
        gov.step()
    moves = [(a, b) for a, b, _ in gov.transitions]
    assert (0, 1) in moves and (1, 2) in moves       # walked the ladder down
    assert gov.rung == 2                             # settled on int8
    # steady state: the served rung's telemetry stays under budget.  Use
    # the calibration key so the check shares the cost basis the budget
    # was derived from (per-key start-draw variation must not knife-edge
    # the bound — see the ULP-flakiness memory note)
    res = engine.eval(jnp.asarray(x), jax.random.key(0), policy=gov.current)
    assert float(np.asarray(res.energy_pj).mean()) * 1e-3 <= budget
    assert res.precision == "int8"
    gov.observe(energy_pj=np.asarray(res.energy_pj))
    gov.step()
    assert gov.rung == 2 and gov.rolling_nj <= budget


def test_frontier_calibrated_governor_starts_compliant(engine, xy):
    """With a calibrated frontier, the governor's initial rung is already
    the best point predicted to fit — no breach-and-recover churn."""
    x, y = xy
    frontier = build_frontier(engine, x, y)
    budget = frontier.points[len(frontier) // 2].energy_nj
    gov = EnergyGovernor(frontier, budget_nj=budget,
                         model=engine.energy_model("fp32"), window=len(x))
    assert gov._predicted_nj[gov.rung] <= budget
    for i in range(4):
        res = engine.eval(jnp.asarray(x), jax.random.key(i),
                          policy=gov.current)
        gov.observe(energy_pj=np.asarray(res.energy_pj))
        gov.step()
    assert gov.rolling_nj <= budget


def test_policy_for_budget_clamps_hop_budget(engine, xy):
    """A per-request contract is HARD: the resolved policy's hop budget
    caps even adversarially unconfident lanes, so no single example can
    overspend the request's nJ budget."""
    x, y = xy
    frontier = build_frontier(engine, x, y)
    model = engine.energy_model("fp32")
    gov = EnergyGovernor(frontier, budget_nj=None, model=model)
    budget_nj = 0.9
    pol = gov.policy_for_budget(budget_nj)
    # the clamp is priced at the chosen rung's own precision
    eff = pol.precision if pol.precision is not None else "fp32"
    assert pol.hop_budget == engine.energy_model(eff).hops_within(
        budget_nj * 1e3)
    res = engine.eval(jnp.asarray(x), jax.random.key(3), policy=pol)
    # worst-case lane, not just the mean, honors the contract
    assert float(np.asarray(res.energy_pj).max()) * 1e-3 <= budget_nj

    # a budget below even one hop's cost is unhonorable: loud failure,
    # not a silent ~3x overspend of the "hard" contract
    with pytest.raises(ValueError, match="below one hop"):
        gov.policy_for_budget(1e-6)
    # a budget between one hop and the cheapest frontier point degrades
    # to the cheapest rung, hop-clamped to 1 — and genuinely fits
    one_hop_nj = float(gov.model_for("int8").per_hop_pj) * 1e-3
    small = gov.policy_for_budget(one_hop_nj * 1.01)
    assert small.hop_budget == 1


def test_policy_for_budget_list_ladder_keeps_best_rung():
    """Without a frontier, the hop clamp alone enforces the budget — the
    request keeps the BEST rung's threshold instead of being punished
    twice with the cheapest rung's quality."""
    model = EnergyModel(2, 8, 10, 16)
    best, worst = FogPolicy(threshold=0.7), FogPolicy(threshold=0.1)
    gov = EnergyGovernor([best, worst], budget_nj=None, model=model)
    pol = gov.policy_for_budget(0.4)
    assert pol.threshold == 0.7              # best rung's quality
    assert pol.hop_budget == model.hops_within(400.0)   # budget still hard


def test_per_device_rolling_estimates():
    """Data-parallel telemetry: device-labeled observations feed per-device
    rolling estimates alongside the fleet estimate, and the summary exposes
    the cross-device spread (a skewed replica shows up as a number, not a
    mystery)."""
    model = EnergyModel(2, 8, 10, 16)
    gov = EnergyGovernor([FogPolicy(threshold=0.6)], budget_nj=None,
                         model=model, window=64)
    pj = np.asarray(model.lane_pj(np.asarray([2, 2, 6, 6])))
    gov.observe(energy_pj=pj, devices=np.asarray([0, 0, 1, 1]))
    summary = gov.device_summary()
    assert set(summary) == {0, 1, None}
    assert summary[0]["n"] == 2 and summary[1]["n"] == 2
    assert summary[1]["nj"] > summary[0]["nj"]       # 6 hops > 2 hops
    spread = summary[None]["spread_nj"]
    assert spread == pytest.approx(summary[1]["nj"] - summary[0]["nj"])
    # fleet estimate unchanged by the device labeling
    assert gov.rolling_nj == pytest.approx(float(pj.mean()) * 1e-3)
    with pytest.raises(ValueError, match="devices"):
        gov.observe(energy_pj=pj, devices=np.asarray([0, 1]))


def test_device_estimates_survive_rung_transitions():
    """The per-device view tracks the DEVICE, not the rung: a step-down
    resets the fleet EWMA (it estimated the old rung's cost) but must not
    wipe the per-device skew telemetry."""
    model = EnergyModel(2, 8, 10, 16)
    gov = EnergyGovernor([FogPolicy(threshold=0.5), FogPolicy(threshold=0.1)],
                         budget_nj=0.5, model=model, window=4, warmup=1)
    pj = np.asarray(model.lane_pj(np.full(4, 8)))
    gov.observe(energy_pj=pj, devices=np.asarray([0, 0, 1, 1]))
    gov.step()
    assert gov.rung == 1 and gov.rolling_nj is None     # fleet EWMA reset
    summary = gov.device_summary()
    assert summary[0]["n"] == 2 and summary[1]["n"] == 2  # devices kept
    assert summary[None]["spread_nj"] == pytest.approx(0.0, abs=1e-12)
