"""Hypothesis property sweeps for kernels and core math.

This whole module is gated on ``pytest.importorskip("hypothesis")`` so a
bare interpreter (no dev deps) still collects the suite cleanly; the
deterministic slices of these sweeps live in test_fog_core / test_kernels /
test_optim and always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import top2  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.flash_attention import flash_attention_pallas  # noqa: E402
from repro.optim.compression import compress_int8, decompress_int8  # noqa: E402

pytestmark = pytest.mark.slow


# ----------------------------------------------------------------- top2 ---
@settings(max_examples=50, deadline=None)
@given(st.integers(2, 40), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_top2_property(C, B, seed):
    rng = np.random.default_rng(seed)
    ar = jnp.asarray(rng.normal(size=(B, C)).astype(np.float32))
    m1, m2 = top2(ar)
    srt = np.sort(np.asarray(ar), axis=-1)
    np.testing.assert_allclose(np.asarray(m1), srt[:, -1], atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), srt[:, -2], atol=1e-6)


# ------------------------------------------------- grove_aggregate fused ---
@st.composite
def _hop_states(draw):
    """Hop state with tie-heavy prob rows and a mixed live mask.

    Probabilities are drawn from a SMALL discrete grid, so exact m1 == m2
    ties (the margin-zero case) and near-threshold margins are common —
    exactly the paths the fused kernel's first-max masking must get right.
    """
    B = draw(st.integers(1, 97))
    C = draw(st.integers(2, 27))
    seed = draw(st.integers(0, 2**31 - 1))
    block_b = draw(st.sampled_from([8, 16, 64, 256]))
    thresh = draw(st.sampled_from([0.0, 0.1, 0.25, 0.5, 1.0]))
    rng = np.random.default_rng(seed)
    # grid-valued accumulators: every value in {0, .125, ..., 1} * hops
    prob_acc = rng.integers(0, 9, size=(B, C)).astype(np.float32) / 8.0
    contrib = rng.integers(0, 5, size=(B, C)).astype(np.float32) / 4.0
    live = rng.random(B) > 0.35
    hops = rng.integers(0, 6, size=B).astype(np.int32)
    return prob_acc, contrib, live, hops, np.float32(thresh), block_b


@settings(max_examples=60, deadline=None)
@given(_hop_states())
def test_grove_aggregate_property(state):
    """Fused Pallas hop update == pure-jnp reference on tie-heavy, partly
    dead batches of every alignment (B need not divide block_b)."""
    prob_acc, contrib, live, hops, thresh, block_b = state
    args = (jnp.asarray(prob_acc), jnp.asarray(contrib), jnp.asarray(live),
            jnp.asarray(hops), jnp.asarray(thresh))
    got = ops.grove_aggregate(*args, block_b=block_b)
    want = ref.grove_aggregate_ref(*args)
    for g, w in zip(got, want):
        assert g.shape == w.shape
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-6, atol=1e-6)
    prob, hops2, live2, margin = got
    # dead-lane masking invariants, independent of the reference:
    dead = ~live
    np.testing.assert_array_equal(np.asarray(prob)[dead], prob_acc[dead])
    np.testing.assert_array_equal(np.asarray(hops2)[dead], hops[dead])
    assert not np.asarray(live2)[dead].any()
    # exact ties must yield margin 0 for live lanes (keep hopping)
    prob_n = np.asarray(prob) / np.maximum(np.asarray(hops2), 1)[:, None]
    srt = np.sort(prob_n, axis=-1)
    tie = (srt[:, -1] == srt[:, -2]) & live
    np.testing.assert_allclose(np.asarray(margin)[tie], 0.0, atol=1e-7)


# ------------------------------------------- fused whole-loop backend ------
@st.composite
def _forest_and_policy(draw):
    """A random grove field x a random FogPolicy — the fused backend's
    conformance domain: any (G, t, d, C, F) geometry, any batch alignment,
    scalar or per-lane thresholds, optional per-lane hop budgets, multi-
    output heads, lazy or scan reference loop."""
    G = draw(st.integers(1, 8))
    t = draw(st.integers(1, 4))
    depth = draw(st.integers(1, 5))
    C = draw(st.integers(2, 9))
    F = draw(st.integers(2, 16))
    O = draw(st.integers(1, 2))
    B = draw(st.integers(1, 97))
    block_b = draw(st.sampled_from([8, 32, 64, 256]))
    max_hops = draw(st.integers(1, 2 * G))
    lazy = draw(st.booleans())
    per_lane_thresh = draw(st.booleans())
    with_budget = draw(st.booleans())
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    heads = []
    for _ in range(O):
        feature = rng.integers(0, F, size=(G, t, 2**depth - 1)).astype(np.int32)
        threshold = rng.normal(size=(G, t, 2**depth - 1)).astype(np.float32)
        leaf = rng.dirichlet(np.ones(C),
                             size=(G, t, 2**depth)).astype(np.float32)
        heads.append((feature, threshold, leaf))
    if per_lane_thresh:
        thresh = rng.choice([0.02, 0.1, 0.3, 0.6, 1.1],
                            size=B).astype(np.float32)
    else:
        thresh = np.float32(rng.choice([0.02, 0.1, 0.3, 0.6, 1.1]))
    budget = (rng.integers(1, 2 * G + 1, size=B).astype(np.int32)
              if with_budget else None)
    x = rng.normal(size=(B, F)).astype(np.float32)
    return heads, x, thresh, budget, max_hops, block_b, lazy, seed


@settings(max_examples=40, deadline=None)
@given(_forest_and_policy())
def test_fused_backend_property(case):
    """The one-launch fused kernel == the reference backend on random grove
    fields under random policies: bit-identical hops and labels (the energy
    contract), allclose probabilities, every geometry and alignment."""
    from repro.core import FogEngine, FogPolicy
    from repro.core.grove import GroveCollection
    heads, x, thresh, budget, max_hops, block_b, lazy, seed = case
    gcs = tuple(GroveCollection(jnp.asarray(f), jnp.asarray(t), jnp.asarray(l))
                for f, t, l in heads)
    gc_arg = gcs if len(gcs) > 1 else gcs[0]
    pol = FogPolicy(threshold=jnp.asarray(thresh), max_hops=max_hops,
                    hop_budget=None if budget is None else jnp.asarray(budget))
    key = jax.random.key(seed)
    want = FogEngine(gc_arg, lazy=lazy).eval(x, key, policy=pol)
    got = FogEngine(gc_arg, backend="fused", block_b=block_b,
                    lazy=lazy).eval(x, key, policy=pol)
    np.testing.assert_array_equal(np.asarray(got.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(got.label),
                                  np.asarray(want.label))
    np.testing.assert_allclose(np.asarray(got.proba), np.asarray(want.proba),
                               rtol=1e-6, atol=1e-7)
    # policy invariants, independent of the reference:
    hops = np.asarray(got.hops)
    assert (hops >= 1).all() and (hops <= max_hops).all()
    if budget is not None:
        assert (hops <= budget).all()


# -------------------------------------------------------- tree traversal ---
def _random_forest_arrays(rng, t, depth, C, F):
    n_nodes = 2**depth - 1
    feature = rng.integers(0, F, size=(t, n_nodes)).astype(np.int32)
    threshold = rng.normal(size=(t, n_nodes)).astype(np.float32)
    leaf = rng.dirichlet(np.ones(C), size=(t, 2**depth)).astype(np.float32)
    return feature, threshold, leaf


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 8), depth=st.integers(1, 6),
    C=st.integers(2, 12), F=st.integers(2, 40),
    log_b=st.integers(0, 6), seed=st.integers(0, 2**31 - 1),
)
def test_tree_traverse_property(t, depth, C, F, log_b, seed):
    B = 2**log_b
    rng = np.random.default_rng(seed)
    feature, threshold, leaf = _random_forest_arrays(rng, t, depth, C, F)
    x = rng.normal(size=(B, F)).astype(np.float32)
    got = np.asarray(ops.tree_traverse(feature, threshold, leaf, x, block_b=B))
    want = np.asarray(ref.tree_traverse_ref(
        jnp.asarray(feature), jnp.asarray(threshold), jnp.asarray(leaf),
        jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # invariant: output rows are distributions (leaves are dirichlet rows)
    np.testing.assert_allclose(got.sum(-1), 1.0, rtol=1e-5)
    assert (got >= -1e-7).all()


# ------------------------------------------------------- flash attention ---
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.sampled_from([16, 32, 64]),
       st.sampled_from([(4, 2), (4, 1), (8, 8)]),
       st.sampled_from([8, 16, 32]), st.integers(0, 2**31 - 1))
def test_flash_attention_property(B, S, HK, D, seed):
    H, K = HK
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, blk_q=16, blk_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # row-stochastic invariant: attention output of constant v is constant
    vc = jnp.ones_like(v)
    out_c = flash_attention_pallas(q, k, vc, causal=True, blk_q=16, blk_k=16)
    np.testing.assert_allclose(np.asarray(out_c), 1.0, rtol=1e-5)


# ----------------------------------------------------------- compression ---
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200))
def test_int8_roundtrip_bounded_error(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.01, 100))
    q, scale = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-9   # half-ULP of the grid


# ------------------------------------------------ ForestPack quantization ---
@st.composite
def _random_field(draw):
    G = draw(st.integers(1, 6))
    t = draw(st.integers(1, 4))
    depth = draw(st.integers(1, 5))
    C = draw(st.integers(2, 9))
    F = draw(st.integers(2, 16))
    B = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    pad_frac = draw(st.sampled_from([0.0, 0.3, 0.7]))
    rng = np.random.default_rng(seed)
    n_nodes = 2**depth - 1
    feature = rng.integers(0, F, size=(G, t, n_nodes)).astype(np.int32)
    threshold = (rng.normal(size=(G, t, n_nodes))
                 * rng.uniform(0.01, 50)).astype(np.float32)
    # complete-tree padding: some nodes carry the +inf "go left" sentinel
    threshold[rng.random((G, t, n_nodes)) < pad_frac] = np.inf
    leaf = rng.dirichlet(np.ones(C), size=(G, t, 2**depth)).astype(np.float32)
    x = rng.normal(size=(B, F)).astype(np.float32)
    return feature, threshold, leaf, x, seed


@settings(max_examples=30, deadline=None)
@given(_random_field())
def test_int8_pack_quantization_bounds(case):
    """ForestPack int8 invariants on random grove fields: dequant error is
    half a per-tree grid step (finite values), ±inf padding survives
    exactly, and — against a hybrid field walking the SAME paths with fp32
    leaves — full-hop probabilities shift by at most half a leaf grid step
    and MaxDiff margins by at most a full step."""
    from repro.core import FogEngine, FogPolicy, maxdiff
    from repro.core.grove import GroveCollection
    from repro.forest.pack import ForestPack
    feature, threshold, leaf, x, seed = case
    gc = GroveCollection(jnp.asarray(feature), jnp.asarray(threshold),
                         jnp.asarray(leaf))
    pack = ForestPack.from_groves(gc, "int8")
    _, thr_dq, leaf_dq = pack.dequantize()
    thr_dq, leaf_dq = np.asarray(thr_dq[0]), np.asarray(leaf_dq[0])
    finite = np.isfinite(threshold)
    np.testing.assert_array_equal(thr_dq[~finite], threshold[~finite])
    ts = np.broadcast_to(np.asarray(pack.thr_scale[0]), threshold.shape)
    assert (np.abs(thr_dq[finite] - threshold[finite])
            <= 0.5 * ts[finite] + 1e-6).all()
    ls = np.broadcast_to(np.asarray(pack.leaf_scale[0]), leaf.shape)
    assert (np.abs(leaf_dq - leaf) <= 0.5 * ls + 1e-6).all()

    hybrid = GroveCollection(jnp.asarray(feature), jnp.asarray(thr_dq),
                             jnp.asarray(leaf))
    key = jax.random.key(seed)
    pol = FogPolicy(threshold=1.1, max_hops=gc.n_groves)    # full hops
    want = FogEngine(hybrid).eval(x, key, policy=pol)
    got = FogEngine(gc, precision="int8").eval(x, key, policy=pol)
    np.testing.assert_array_equal(np.asarray(got.hops),
                                  np.asarray(want.hops))
    bound = 0.5 * float(np.asarray(pack.leaf_scale).max()) + 1e-5
    err = np.abs(np.asarray(got.proba) - np.asarray(want.proba)).max()
    assert err <= bound, (err, bound)
    m_err = np.abs(np.asarray(maxdiff(got.proba))
                   - np.asarray(maxdiff(want.proba))).max()
    assert m_err <= 2 * bound
