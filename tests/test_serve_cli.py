"""Serve CLI regressions (launch/serve.py).

The --fog-backend bug: the CLI hardcoded its backend choices and silently
fell out of sync with the engine registry (``ring`` was unreachable).  The
parser now derives choices from ``core.policy.BACKENDS`` / ``PRECISIONS``;
these tests pin that contract so a new backend or precision can never be
un-servable again.
"""
from repro.core.policy import BACKENDS, PRECISIONS
from repro.launch.serve import build_parser


def _actions():
    return {a.dest: a for a in build_parser()._actions}


def test_fog_backend_choices_track_engine_registry():
    acts = _actions()
    assert list(acts["fog_backend"].choices) == list(BACKENDS)
    assert "ring" in acts["fog_backend"].choices


def test_fog_precision_choices_track_pack_registry():
    acts = _actions()
    assert list(acts["fog_precision"].choices) == list(PRECISIONS)


def test_data_parallel_knobs_exposed():
    acts = _actions()
    assert acts["devices"].default == 1
    assert acts["max_queue"].default is None
    assert list(acts["shed_policy"].choices) == ["reject", "oldest"]


def test_every_backend_parses():
    ap = build_parser()
    for b in BACKENDS:
        args = ap.parse_args(["--arch", "x", "--fog", "--fog-backend", b])
        assert args.fog_backend == b
