"""Sharding rules: every param/cache leaf gets a valid, divisible spec."""
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import ARCHS
    from repro.launch.mesh import make_production_mesh, dp_axes
    from repro.launch.sharding import param_shardings, cache_shardings
    from repro.models import transformer as T
    from functools import partial

    for multi in (False, True):
        mesh = make_production_mesh(multi_pod=multi)
        for name, cfg in ARCHS.items():
            params_shape = jax.eval_shape(
                lambda k: T.init_params(cfg, k, jnp.bfloat16), jax.random.key(0))
            specs = param_shardings(cfg, mesh, params_shape)
            # validity: every named axis dim divides the leaf dim
            def check(leaf, spec):
                shape = leaf.shape
                for i, part in enumerate(spec):
                    if part is None:
                        continue
                    axes = part if isinstance(part, tuple) else (part,)
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    assert shape[i] % size == 0, (name, shape, spec, i)
            jax.tree.map(check, params_shape, specs)
            # at least the big leaves are sharded (not fully replicated)
            # kv projections with n_kv_heads < TP width replicate by
            # design (GQA); everything >=50M elements must shard
            big = [(l, s) for l, s in zip(jax.tree.leaves(params_shape),
                                          jax.tree.leaves(specs))
                   if np.prod(l.shape) > 5e7]
            assert all(any(p is not None for p in s) for _, s in big), name
            cache_shape = jax.eval_shape(
                partial(T.cache_init, cfg, 128, 1024, jnp.bfloat16))
            cspecs = cache_shardings(cfg, mesh, cache_shape)
            jax.tree.map(check, cache_shape, cspecs)
    print("SHARDING-OK")
""")


def test_sharding_rules_subprocess():
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # forced-host-device scripts must not probe a real TPU: the
             # libtpu worker handshake hangs ~8 min before falling back
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDING-OK" in proc.stdout
