"""Device trainer (forest/grow.py): shared-grid binning, host parity,
determinism, and serving the trained forest through the full pipeline.

The contract under test: both trainers search the SAME candidate grid with
the SAME tie order, so with the randomness pinned (bootstrap=False,
max_features="all") they must emit bit-identical tree structure; with tied
gains (duplicate feature values) structure may diverge at fp32-vs-fp64
precision but the ensembles must still agree on labels.
"""
import jax
import numpy as np
import pytest

from repro.forest.grow import grow_forest
from repro.forest.rf import rf_predict
from repro.forest.train import (GAIN_EPS, TrainConfig, _best_split, _gini,
                                bin_features, quantile_bin_edges,
                                train_random_forest)


@pytest.fixture(scope="module")
def small(ds_penbased):
    """(x_train, y_train, x_test, y_test, C) subset: fast host training."""
    ds = ds_penbased
    # 2000 rows: every gain in the UNTIED configs is genuinely untied (no
    # fp32-vs-fp64 near-tie flips; the dataset generator is seed-frozen,
    # so this property is stable)
    return (ds.x_train[:2000], ds.y_train[:2000], ds.x_test, ds.y_test,
            ds.n_classes)


UNTIED = dict(n_trees=3, max_depth=4, bootstrap=False, max_features="all",
              seed=0)


# ---------------------------------------------------------------- binning

def test_bin_edges_dedupe_constant_and_binary():
    """Regression: low-cardinality columns must not produce duplicate
    candidate thresholds (historically np.quantile emitted q copies)."""
    rng = np.random.default_rng(0)
    n = 500
    x = np.stack([
        np.full(n, 3.0),                       # constant
        (rng.random(n) < 0.4).astype(float),   # binary
        rng.normal(size=n),                    # continuous
    ], axis=1).astype(np.float32)
    edges = quantile_bin_edges(x, 16)
    assert edges.shape == (3, 16)
    for f in range(3):
        fin = edges[f][np.isfinite(edges[f])]
        # deduplicated and sorted; +inf padding at the tail
        assert len(np.unique(fin)) == len(fin)
        assert np.all(np.diff(fin) > 0)
        assert np.all(np.isinf(edges[f][len(fin):]))
    assert np.isfinite(edges[0]).sum() == 1          # constant: one edge
    assert np.isfinite(edges[1]).sum() <= 3          # binary: tiny grid
    assert np.isfinite(edges[2]).sum() == 16         # continuous: full grid

    bins = bin_features(x, edges)
    assert bins.dtype == np.uint8
    # bin semantics: bin = #edges strictly below x, so x > edges[f, j]
    # exactly when bin > j
    for f in range(3):
        for j in range(16):
            np.testing.assert_array_equal(bins[:, f] > j,
                                          x[:, f] > edges[f, j])
    assert np.all(bins[:, 0] == 0)                   # x > const is false


def _brute_best_split(x, y, n_classes, feat_ids, cfg, paid, edges):
    """Scalar-loop oracle for _best_split (same tie order: lowest feature,
    then lowest threshold, strict improvement only)."""
    n = len(y)
    parent = np.bincount(y, minlength=n_classes).astype(np.float64)
    parent_imp = _gini(parent)
    best = None
    for f in sorted(feat_ids):
        for j in range(edges.shape[1]):
            thr = edges[f, j]
            right = x[:, f] > thr
            n_r = int(right.sum())
            n_l = n - n_r
            if n_r < cfg.min_samples_leaf or n_l < cfg.min_samples_leaf:
                continue
            rc = np.bincount(y[right], minlength=n_classes).astype(np.float64)
            lc = parent - rc
            gain = parent_imp - (n_l * _gini(lc) + n_r * _gini(rc)) / n
            if cfg.feature_cost is not None and cfg.cost_weight:
                gain -= cfg.cost_weight * cfg.feature_cost[f] * (not paid[f])
            if gain <= GAIN_EPS:
                continue
            if best is None or gain > best[2] + 1e-12:
                best = (f, float(thr), float(gain))
    return best


@pytest.mark.parametrize("seed,with_cost", [(0, False), (1, False),
                                            (2, True), (3, True)])
def test_vectorized_best_split_matches_bruteforce(seed, with_cost):
    rng = np.random.default_rng(seed)
    n, F, C = 120, 6, 3
    # integer-valued features force duplicate thresholds and tied gains,
    # exercising the dedupe + tie-order paths
    x = rng.integers(0, 5, size=(n, F)).astype(np.float32)
    y = ((x[:, 0] + x[:, 1] > 4).astype(np.int32)
         + (x[:, 2] > 2).astype(np.int32))
    cfg = TrainConfig(min_samples_leaf=2,
                      feature_cost=(np.linspace(0.5, 2.0, F).astype(np.float32)
                                    if with_cost else None),
                      cost_weight=0.05 if with_cost else 0.0)
    edges = quantile_bin_edges(x, 8)
    feat_ids = rng.choice(F, size=4, replace=False)
    paid = np.zeros(F, bool)
    paid[feat_ids[0]] = True
    got = _best_split(x, y, C, feat_ids, cfg, paid, edges)
    want = _brute_best_split(x, y, C, feat_ids, cfg, paid, edges)
    if want is None:
        assert got is None
        return
    assert got is not None
    assert (got[0], got[1]) == (want[0], want[1])
    assert got[2] == pytest.approx(want[2], abs=1e-9)


# ----------------------------------------------------------- host parity

def test_host_device_identical_structure_untied(small):
    """bootstrap=False + max_features='all' removes all randomness: the two
    trainers search the same grid with the same tie order and must emit
    bit-identical feature/threshold tables."""
    x, y, xt, yt, C = small
    fh = train_random_forest(x, y, C, TrainConfig(trainer="host", **UNTIED))
    fd = train_random_forest(x, y, C, TrainConfig(trainer="device", **UNTIED))
    np.testing.assert_array_equal(fh.feature, fd.feature)
    np.testing.assert_array_equal(fh.threshold, fd.threshold)
    np.testing.assert_allclose(fh.leaf, fd.leaf, atol=1e-6)


def test_host_device_label_agreement_tied(small):
    """Integer-quantized features create tied gains where fp32-vs-fp64
    precision may pick different (equally good) splits; the ensembles must
    still agree on >=99% of test labels."""
    x, y, xt, yt, C = small
    xq = np.round(x).astype(np.float32)
    xtq = np.round(xt).astype(np.float32)
    kw = dict(n_trees=8, max_depth=6, bootstrap=False, max_features="all",
              seed=0)
    fh = train_random_forest(xq, y, C, TrainConfig(trainer="host", **kw))
    fd = train_random_forest(xq, y, C, TrainConfig(trainer="device", **kw))
    ph = np.asarray(rf_predict(fh, xtq))
    pd = np.asarray(rf_predict(fd, xtq))
    assert (ph == pd).mean() >= 0.99


def test_feature_cost_changes_splits_identically(small):
    """The budgeted criterion must steer BOTH trainers the same way: with
    the penalty on, structures still match bit-for-bit, and differ from
    the unpenalized structures (the budget actually changed choices)."""
    x, y, xt, yt, C = small
    F = x.shape[1]
    cost = dict(feature_cost=np.linspace(1.0, 3.0, F).astype(np.float32),
                cost_weight=0.05)
    fh = train_random_forest(x, y, C,
                             TrainConfig(trainer="host", **UNTIED, **cost))
    fd = train_random_forest(x, y, C,
                             TrainConfig(trainer="device", **UNTIED, **cost))
    np.testing.assert_array_equal(fh.feature, fd.feature)
    np.testing.assert_array_equal(fh.threshold, fd.threshold)
    free = train_random_forest(x, y, C,
                               TrainConfig(trainer="host", **UNTIED))
    assert not np.array_equal(fh.feature, free.feature)


# -------------------------------------------------- determinism / config

def test_device_trainer_bit_reproducible(small):
    """Two same-seed runs (bootstrap + sqrt subsampling live) must produce
    bit-identical TensorForest tables; a different seed must not."""
    x, y, *_, C = small
    cfg = TrainConfig(n_trees=4, max_depth=4, seed=7, trainer="device")
    a = grow_forest(x, y, C, cfg)
    b = grow_forest(x, y, C, cfg)
    np.testing.assert_array_equal(a.feature, b.feature)
    np.testing.assert_array_equal(a.threshold, b.threshold)
    np.testing.assert_array_equal(a.leaf, b.leaf)
    import dataclasses
    c = grow_forest(x, y, C, dataclasses.replace(cfg, seed=8))
    assert not (np.array_equal(a.feature, c.feature)
                and np.array_equal(a.threshold, c.threshold))


def test_device_trainer_bootstrap_sqrt_accuracy(ds_penbased):
    """Default randomized config trains a usable forest end to end."""
    ds = ds_penbased
    f = train_random_forest(
        ds.x_train, ds.y_train, ds.n_classes,
        TrainConfig(n_trees=8, max_depth=6, seed=0, trainer="device"))
    pred = np.asarray(rf_predict(f, ds.x_test))
    assert (pred == ds.y_test).mean() > 0.85


def test_grow_validates_config(small):
    x, y, *_, C = small
    with pytest.raises(ValueError, match="min_samples_leaf"):
        grow_forest(x, y, C, TrainConfig(min_samples_leaf=0,
                                         trainer="device"))
    with pytest.raises(ValueError, match="max_depth"):
        grow_forest(x, y, C, TrainConfig(max_depth=0, trainer="device"))
    with pytest.raises(ValueError, match="unknown trainer"):
        train_random_forest(x, y, C, TrainConfig(trainer="gpu"))


# ------------------------------------------------------- kernel / serving

def test_histogram_pallas_matches_scatter():
    """The Pallas one-hot kernel (interpret mode) and the XLA segment-sum
    path must produce identical fp32 counts."""
    from repro.kernels.histogram import (histogram_level_pallas,
                                         histogram_level_scatter)
    rng = np.random.default_rng(0)
    T, N, F, B, C, nodes = 2, 96, 3, 5, 3, 4
    node = rng.integers(0, nodes, size=(T, N)).astype(np.int32)
    y = rng.integers(0, C, size=N).astype(np.int32)
    w = rng.integers(0, 3, size=(T, N)).astype(np.float32)  # bootstrap-like
    bins = rng.integers(0, B, size=(N, F)).astype(np.int32)
    kw = dict(n_nodes=nodes, n_bins=B, n_classes=C)
    got = histogram_level_pallas(node, y, w, bins, block_n=32, block_r=8,
                                 block_f=2, interpret=True, **kw)
    want = histogram_level_scatter(node, y, w, bins, **kw)
    assert got.shape == (T, nodes, F, B, C)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # counts are exact: every sample lands in exactly one (node, bin, class)
    np.testing.assert_allclose(np.asarray(want)[:, :, 0].sum(axis=(1, 2, 3)),
                               w.sum(axis=1))


def test_device_forest_serves_identically_across_backends(small):
    """A device-trained forest must feed split/ForestPack and serve with
    bit-identical labels on all four engine backends."""
    from repro.core import FogEngine, FogPolicy, split
    from repro.forest.pack import ForestPack
    x, y, xt, yt, C = small
    f = train_random_forest(x, y, C,
                            TrainConfig(n_trees=4, max_depth=4, seed=0,
                                        trainer="device"))
    gc = split(f, 2)
    pack = ForestPack.from_groves(gc)
    policy = FogPolicy(threshold=0.3, max_hops=gc.n_groves)
    key = jax.random.key(0)
    mesh = jax.make_mesh((1,), ("grove",))
    ref = FogEngine(gc, policy=policy).eval(xt, key)
    for backend in ("pallas", "fused", "ring"):
        eng = FogEngine(gc, backend=backend, policy=policy,
                        **({"mesh": mesh} if backend == "ring" else {}))
        res = eng.eval(xt, key)
        np.testing.assert_array_equal(np.asarray(res.label),
                                      np.asarray(ref.label))
        np.testing.assert_array_equal(np.asarray(res.hops),
                                      np.asarray(ref.hops))


def test_sklearn_trainer_knob(small):
    """FogClassifier(trainer=...) plumbs through to TrainConfig; the
    untied facade fits produce identical packed models."""
    from repro.sklearn import FogClassifier
    x, y, xt, yt, C = small
    kw = dict(n_trees=4, grove_size=2, max_depth=4, seed=0,
              train_cfg=TrainConfig(bootstrap=False, max_features="all"))
    host = FogClassifier(**kw, trainer="host").fit(x, y)
    dev = FogClassifier(**kw, trainer="device").fit(x, y)
    assert host.get_params()["trainer"] == "host"
    np.testing.assert_array_equal(host.forest_.feature, dev.forest_.feature)
    np.testing.assert_array_equal(dev.predict(xt[:256]),
                                  host.predict(xt[:256]))
