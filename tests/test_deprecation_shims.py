"""Deprecation shims: each legacy entry point must (a) fire a real
``DeprecationWarning`` and (b) still return results identical to the
canonical ``FogEngine.eval(x, key, policy=FogPolicy(...))`` call.

One test per shim — `fog_eval`, `fog_eval_multioutput`, `fog_eval_lazy`,
`fog_ring_eval`, the positional ``eval(x, key, thresh, max_hops)`` form,
`HopMeter`, and the batcher's ``meter=`` kwarg — so a future cleanup that
drops a shim (or silences its warning) fails loudly.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FogEngine, FogPolicy, HopMeter, fog_eval,
                        fog_eval_lazy, fog_eval_multioutput, split)
from repro.core.fog_ring import fog_ring_eval


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)


@pytest.fixture(scope="module")
def x128(trained):
    ds, _ = trained
    return jnp.asarray(ds.x_test[:128])


def _canonical(gc, x, key, thresh=0.3, lazy=False):
    return FogEngine(gc, lazy=lazy).eval(
        x, key, policy=FogPolicy(threshold=thresh, max_hops=gc.n_groves))


def _assert_same(res, want):
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(res.label),
                                  np.asarray(want.label))
    np.testing.assert_array_equal(np.asarray(res.proba),
                                  np.asarray(want.proba))


def test_fog_eval_shim_warns_and_matches(gc, x128):
    key = jax.random.key(2)
    with pytest.warns(DeprecationWarning, match="fog_eval is deprecated"):
        res = fog_eval(gc, x128, key, 0.3, gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key))


def test_fog_eval_lazy_shim_warns_and_matches(gc, x128):
    key = jax.random.key(3)
    with pytest.warns(DeprecationWarning,
                      match="fog_eval_lazy is deprecated"):
        res = fog_eval_lazy(gc, x128, key, 0.3, gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key, lazy=True))


def test_fog_eval_multioutput_shim_warns_and_matches(
        trained, rf8_penbased, rf8_noisy_penbased):
    ds, _ = trained
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:96])
    key = jax.random.key(5)
    with pytest.warns(DeprecationWarning,
                      match="fog_eval_multioutput is deprecated"):
        res = fog_eval_multioutput(gcs, x, key, 0.3, 4)
    want = FogEngine(gcs).eval(
        x, key, policy=FogPolicy(threshold=0.3, max_hops=4))
    _assert_same(res, want)


def test_fog_ring_eval_shim_warns_and_matches(gc, x128):
    mesh = jax.make_mesh((1,), ("grove",))
    key = jax.random.key(7)
    with pytest.warns(DeprecationWarning,
                      match="fog_ring_eval is deprecated"):
        proba, hops = fog_ring_eval(gc, x128, key, 0.3, gc.n_groves, mesh)
    want = FogEngine(gc, backend="ring", mesh=mesh).eval(
        x128, key, policy=FogPolicy(threshold=0.3, max_hops=gc.n_groves))
    np.testing.assert_array_equal(np.asarray(hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(proba), np.asarray(want.proba))


def test_positional_eval_shim_warns_and_matches(gc, x128):
    key = jax.random.key(11)
    eng = FogEngine(gc)
    with pytest.warns(DeprecationWarning,
                      match=r"eval\(x, key, thresh, max_hops\) is deprecated"):
        res = eng.eval(x128, key, 0.3, max_hops=gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key))


def test_hop_meter_shim_warns_and_matches(gc, x128):
    """HopMeter is redundant with EvalReport telemetry: constructing one
    warns, but the accounting still matches the report's hops."""
    with pytest.warns(DeprecationWarning, match="HopMeter is deprecated"):
        meter = HopMeter()
    res = FogEngine(gc).eval(x128, jax.random.key(2),
                             policy=FogPolicy(threshold=0.3))
    meter.update(res.hops)
    assert meter.n_events == x128.shape[0]
    assert meter.mean_hops == float(np.asarray(res.hops).mean())


def test_batcher_meter_kwarg_warns_and_still_feeds(gc, x128):
    from repro.serve.scheduler import ContinuousBatcher, Request

    def decode_fn(tokens, lengths):
        n = tokens.shape[0]
        logits = np.zeros((n, 8), np.float32)
        return jnp.asarray(logits), jnp.asarray(np.full((n,), 2))

    with pytest.warns(DeprecationWarning, match="HopMeter is deprecated"):
        meter = HopMeter()
    with pytest.warns(DeprecationWarning, match="meter=.*deprecated"):
        batcher = ContinuousBatcher(2, decode_fn,
                                    lambda slot, prompt: len(prompt),
                                    eos_id=-1, meter=meter)
    batcher.submit(Request(rid=0, prompt=np.asarray([1]), max_new_tokens=2))
    batcher.run()
    # the shimmed meter and the canonical stats agree
    assert meter.n_events == batcher.stats.n_events == 2
    assert meter.mean_hops == batcher.stats.mean_hops == 2.0


def test_batcher_meter_attribute_read_warns_and_matches():
    """Legacy READERS of batcher.meter (never passed one in) get a working
    shim seeded from stats, not an AttributeError."""
    from repro.serve.scheduler import ContinuousBatcher, Request

    def decode_fn(tokens, lengths):
        n = tokens.shape[0]
        return jnp.asarray(np.zeros((n, 8), np.float32)), \
            jnp.asarray(np.full((n,), 3))

    batcher = ContinuousBatcher(2, decode_fn,
                                lambda slot, prompt: len(prompt), eos_id=-1)
    batcher.submit(Request(rid=0, prompt=np.asarray([1]), max_new_tokens=2))
    batcher.run()
    with pytest.warns(DeprecationWarning, match="meter is deprecated"):
        meter = batcher.meter
    assert meter.n_events == batcher.stats.n_events == 2
    assert meter.mean_hops == 3.0
    assert "hops/event" in meter.summary(8)


def test_canonical_calls_are_warning_free(gc, x128):
    """The replacement forms must not trip any DeprecationWarning."""
    key = jax.random.key(13)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _canonical(gc, x128, key)
        FogEngine(gc, backend="fused").eval(
            x128, key, policy=FogPolicy(threshold=0.3))
        # the serving path's canonical telemetry is warning-free too
        from repro.serve.scheduler import ContinuousBatcher
        ContinuousBatcher(2, lambda t, l: (jnp.zeros((2, 8)), None),
                          lambda slot, prompt: len(prompt))
