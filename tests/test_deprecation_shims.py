"""Deprecation shims: each legacy entry point must (a) fire a real
``DeprecationWarning`` and (b) still return results identical to the
canonical ``FogEngine.eval(x, key, policy=FogPolicy(...))`` call.

One test per shim — `fog_eval`, `fog_eval_multioutput`, `fog_eval_lazy`,
`fog_ring_eval`, and the positional ``eval(x, key, thresh, max_hops)``
form — so a future cleanup that drops a shim (or silences its warning)
fails loudly.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FogEngine, FogPolicy, fog_eval, fog_eval_lazy,
                        fog_eval_multioutput, split)
from repro.core.fog_ring import fog_ring_eval


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)


@pytest.fixture(scope="module")
def x128(trained):
    ds, _ = trained
    return jnp.asarray(ds.x_test[:128])


def _canonical(gc, x, key, thresh=0.3, lazy=False):
    return FogEngine(gc, lazy=lazy).eval(
        x, key, policy=FogPolicy(threshold=thresh, max_hops=gc.n_groves))


def _assert_same(res, want):
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(res.label),
                                  np.asarray(want.label))
    np.testing.assert_array_equal(np.asarray(res.proba),
                                  np.asarray(want.proba))


def test_fog_eval_shim_warns_and_matches(gc, x128):
    key = jax.random.key(2)
    with pytest.warns(DeprecationWarning, match="fog_eval is deprecated"):
        res = fog_eval(gc, x128, key, 0.3, gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key))


def test_fog_eval_lazy_shim_warns_and_matches(gc, x128):
    key = jax.random.key(3)
    with pytest.warns(DeprecationWarning,
                      match="fog_eval_lazy is deprecated"):
        res = fog_eval_lazy(gc, x128, key, 0.3, gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key, lazy=True))


def test_fog_eval_multioutput_shim_warns_and_matches(
        trained, rf8_penbased, rf8_noisy_penbased):
    ds, _ = trained
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:96])
    key = jax.random.key(5)
    with pytest.warns(DeprecationWarning,
                      match="fog_eval_multioutput is deprecated"):
        res = fog_eval_multioutput(gcs, x, key, 0.3, 4)
    want = FogEngine(gcs).eval(
        x, key, policy=FogPolicy(threshold=0.3, max_hops=4))
    _assert_same(res, want)


def test_fog_ring_eval_shim_warns_and_matches(gc, x128):
    mesh = jax.make_mesh((1,), ("grove",))
    key = jax.random.key(7)
    with pytest.warns(DeprecationWarning,
                      match="fog_ring_eval is deprecated"):
        proba, hops = fog_ring_eval(gc, x128, key, 0.3, gc.n_groves, mesh)
    want = FogEngine(gc, backend="ring", mesh=mesh).eval(
        x128, key, policy=FogPolicy(threshold=0.3, max_hops=gc.n_groves))
    np.testing.assert_array_equal(np.asarray(hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(proba), np.asarray(want.proba))


def test_positional_eval_shim_warns_and_matches(gc, x128):
    key = jax.random.key(11)
    eng = FogEngine(gc)
    with pytest.warns(DeprecationWarning,
                      match=r"eval\(x, key, thresh, max_hops\) is deprecated"):
        res = eng.eval(x128, key, 0.3, max_hops=gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key))


def test_canonical_calls_are_warning_free(gc, x128):
    """The replacement forms must not trip any DeprecationWarning."""
    key = jax.random.key(13)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _canonical(gc, x128, key)
        FogEngine(gc, backend="fused").eval(
            x128, key, policy=FogPolicy(threshold=0.3))
