"""Deprecation shims: each legacy entry point must (a) fire a real
``DeprecationWarning`` and (b) still return results identical to the
canonical ``FogEngine.eval(x, key, policy=FogPolicy(...))`` call.

One test per shim — `fog_eval`, `fog_eval_multioutput`, `fog_eval_lazy`,
`fog_ring_eval`, the positional ``eval(x, key, thresh, max_hops)`` form,
`HopMeter`, and the batcher's ``meter=`` kwarg — so a future cleanup that
drops a shim (or silences its warning) fails loudly.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FogEngine, FogPolicy, HopMeter, fog_eval,
                        fog_eval_lazy, fog_eval_multioutput, split)
from repro.core.fog_ring import fog_ring_eval


@pytest.fixture(scope="module")
def gc(trained):
    _, rf = trained
    return split(rf, 2)


@pytest.fixture(scope="module")
def x128(trained):
    ds, _ = trained
    return jnp.asarray(ds.x_test[:128])


def _canonical(gc, x, key, thresh=0.3, lazy=False):
    return FogEngine(gc, lazy=lazy).eval(
        x, key, policy=FogPolicy(threshold=thresh, max_hops=gc.n_groves))


def _assert_same(res, want):
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(res.label),
                                  np.asarray(want.label))
    np.testing.assert_array_equal(np.asarray(res.proba),
                                  np.asarray(want.proba))


def test_fog_eval_shim_warns_and_matches(gc, x128):
    key = jax.random.key(2)
    with pytest.warns(DeprecationWarning, match="fog_eval is deprecated"):
        res = fog_eval(gc, x128, key, 0.3, gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key))


def test_fog_eval_lazy_shim_warns_and_matches(gc, x128):
    key = jax.random.key(3)
    with pytest.warns(DeprecationWarning,
                      match="fog_eval_lazy is deprecated"):
        res = fog_eval_lazy(gc, x128, key, 0.3, gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key, lazy=True))


def test_fog_eval_multioutput_shim_warns_and_matches(
        trained, rf8_penbased, rf8_noisy_penbased):
    ds, _ = trained
    gcs = (split(rf8_penbased, 2), split(rf8_noisy_penbased, 2))
    x = jnp.asarray(ds.x_test[:96])
    key = jax.random.key(5)
    with pytest.warns(DeprecationWarning,
                      match="fog_eval_multioutput is deprecated"):
        res = fog_eval_multioutput(gcs, x, key, 0.3, 4)
    want = FogEngine(gcs).eval(
        x, key, policy=FogPolicy(threshold=0.3, max_hops=4))
    _assert_same(res, want)


def test_fog_ring_eval_shim_warns_and_matches(gc, x128):
    mesh = jax.make_mesh((1,), ("grove",))
    key = jax.random.key(7)
    with pytest.warns(DeprecationWarning,
                      match="fog_ring_eval is deprecated"):
        proba, hops = fog_ring_eval(gc, x128, key, 0.3, gc.n_groves, mesh)
    want = FogEngine(gc, backend="ring", mesh=mesh).eval(
        x128, key, policy=FogPolicy(threshold=0.3, max_hops=gc.n_groves))
    np.testing.assert_array_equal(np.asarray(hops), np.asarray(want.hops))
    np.testing.assert_array_equal(np.asarray(proba), np.asarray(want.proba))


def test_positional_eval_shim_warns_and_matches(gc, x128):
    key = jax.random.key(11)
    eng = FogEngine(gc)
    with pytest.warns(DeprecationWarning,
                      match=r"eval\(x, key, thresh, max_hops\) is deprecated"):
        res = eng.eval(x128, key, 0.3, max_hops=gc.n_groves)
    _assert_same(res, _canonical(gc, x128, key))


def test_hop_meter_shim_warns_and_matches(gc, x128):
    """HopMeter is redundant with EvalReport telemetry: constructing one
    warns, but the accounting still matches the report's hops."""
    with pytest.warns(DeprecationWarning, match="HopMeter is deprecated"):
        meter = HopMeter()
    res = FogEngine(gc).eval(x128, jax.random.key(2),
                             policy=FogPolicy(threshold=0.3))
    meter.update(res.hops)
    assert meter.n_events == x128.shape[0]
    assert meter.mean_hops == float(np.asarray(res.hops).mean())


def test_batcher_meter_kwarg_warns_and_still_feeds(gc, x128):
    from repro.serve.scheduler import ContinuousBatcher, Request

    def decode_fn(tokens, lengths):
        n = tokens.shape[0]
        logits = np.zeros((n, 8), np.float32)
        return jnp.asarray(logits), jnp.asarray(np.full((n,), 2))

    with pytest.warns(DeprecationWarning, match="HopMeter is deprecated"):
        meter = HopMeter()
    with pytest.warns(DeprecationWarning, match="meter=.*deprecated"):
        batcher = ContinuousBatcher(2, decode_fn,
                                    lambda slot, prompt: len(prompt),
                                    eos_id=-1, meter=meter)
    batcher.submit(Request(rid=0, prompt=np.asarray([1]), max_new_tokens=2))
    batcher.run()
    # the shimmed meter and the canonical stats agree
    assert meter.n_events == batcher.stats.n_events == 2
    assert meter.mean_hops == batcher.stats.mean_hops == 2.0


def test_batcher_meter_attribute_read_warns_and_matches():
    """Legacy READERS of batcher.meter (never passed one in) get a working
    shim seeded from stats, not an AttributeError."""
    from repro.serve.scheduler import ContinuousBatcher, Request

    def decode_fn(tokens, lengths):
        n = tokens.shape[0]
        return jnp.asarray(np.zeros((n, 8), np.float32)), \
            jnp.asarray(np.full((n,), 3))

    batcher = ContinuousBatcher(2, decode_fn,
                                lambda slot, prompt: len(prompt), eos_id=-1)
    batcher.submit(Request(rid=0, prompt=np.asarray([1]), max_new_tokens=2))
    batcher.run()
    with pytest.warns(DeprecationWarning, match="meter is deprecated"):
        meter = batcher.meter
    assert meter.n_events == batcher.stats.n_events == 2
    assert meter.mean_hops == 3.0
    assert "hops/event" in meter.summary(8)


def test_canonical_calls_are_warning_free(gc, x128):
    """The replacement forms must not trip any DeprecationWarning."""
    key = jax.random.key(13)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _canonical(gc, x128, key)
        FogEngine(gc, backend="fused").eval(
            x128, key, policy=FogPolicy(threshold=0.3))
        # the serving path's canonical telemetry is warning-free too
        from repro.serve.scheduler import ContinuousBatcher
        ContinuousBatcher(2, lambda t, l: (jnp.zeros((2, 8)), None),
                          lambda slot, prompt: len(prompt))


# --------------------------------------------------------------------------
# launch/roofline -> launch/hlo_cost move (the LM HLO cost model relocated;
# repro.launch.roofline is now the FoG RooflineModel)
# --------------------------------------------------------------------------

def test_roofline_module_shim_warns_and_forwards():
    """Legacy ``from repro.launch.roofline import HloCostModel`` style access
    warns and returns the exact hlo_cost object."""
    import repro.launch.hlo_cost as hc
    import repro.launch.roofline as rl

    for name in ("PEAK_FLOPS", "HBM_BW", "HloCostModel",
                 "analytic_model_flops", "_shape_bytes"):
        with pytest.warns(DeprecationWarning, match=f"{name} moved"):
            got = getattr(rl, name)
        assert got is getattr(hc, name)
    # non-moved garbage still raises AttributeError, not a warning
    with pytest.raises(AttributeError):
        rl.no_such_symbol


def test_roofline_shim_objects_still_work():
    """The forwarded HloCostModel parses HLO identically to the new home."""
    import repro.launch.hlo_cost as hc
    import repro.launch.roofline as rl

    hlo = ("HloModule t\n\nENTRY %main (x: f32[8,8]) -> f32[8,8] {\n"
           "  %x = f32[8,8]{1,0} parameter(0)\n"
           "  ROOT %d = f32[8,8]{1,0} dot(%x, %x), "
           "lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n")
    with pytest.warns(DeprecationWarning):
        legacy = rl.HloCostModel(hlo).totals()
    assert legacy == hc.HloCostModel(hlo).totals()


def test_roofline_report_legacy_mode_warns_and_guards_division():
    """The LM dry-run JSONL path in benchmarks.roofline_report is deprecated
    but still callable — now with guarded divisions (chips=0, flops=0)."""
    from benchmarks import roofline_report as rr

    rec = {"arch": "a", "shape": "s", "mesh": "m", "hlo_flops": 0,
           "hlo_bytes": 0, "collective_bytes": 0, "model_flops": 0.0,
           "chips": 0}
    with pytest.warns(DeprecationWarning, match="derive"):
        row = rr.derive(rec)
    assert row["useful_flops_ratio"] == 0.0
    assert row["roofline_fraction"] == 0.0
    with pytest.warns(DeprecationWarning, match="table"):
        lines = rr.table([row])
    assert len(lines) == 3

    # the new engine-roofline entry points are warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        rr.engine_table(rr.engine_rows("BENCH_engine.json"))
