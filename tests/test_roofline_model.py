"""HLO cost model unit tests against hand-crafted HLO text.

The model moved from ``launch/roofline.py`` (now the FoG-specific
RooflineModel — tested in this file too) to ``launch/hlo_cost.py``.
"""
import numpy as np
import pytest

from repro.launch.hlo_cost import (
    HloCostModel, _shape_bytes, analytic_model_flops, collective_bytes_from_hlo,
)
from repro.launch.roofline import (
    HOST_CPU, TPU_V5E, MachineSpec, RooflineModel,
)

HLO = """\
HloModule test

%fused_inner (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  ROOT %e = f32[128,64]{1,0} exponential(%p0)
}

%body (arg: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %arg = (s32[], f32[128,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[128,64]{1,0} get-tuple-element(%arg), index=1
  %w = f32[64,64]{1,0} constant({...})
  %d = f32[128,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %f = f32[128,64]{1,0} fusion(%d), kind=kLoop, calls=%fused_inner
  %ar = f32[128,64]{1,0} all-reduce(%f), replica_groups={}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,64]{1,0}) tuple(%ip, %ar)
}

%cond (arg: (s32[], f32[128,64])) -> pred[] {
  %arg = (s32[], f32[128,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,64]) -> f32[128,64] {
  %x = f32[128,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,64]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[128,64]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1


def test_cost_model_scales_loop_body():
    m = HloCostModel(HLO)
    assert m.entry == "main"
    t = m.totals()
    # dot: 2 * 128*64 * 64 contracted = 1,048,576 flops x 10 trips
    assert t["flops"] == 10 * 2 * 128 * 64 * 64
    # collective: all-reduce result 32 KiB x 10 trips
    assert t["collective_bytes"] == 10 * 128 * 64 * 4
    assert t["collective_by_kind"] == {"all-reduce": 10 * 128 * 64 * 4}


def test_collective_regex_fallback():
    got = collective_bytes_from_hlo(HLO)
    assert got["all-reduce"] == 128 * 64 * 4   # unscaled single-pass parse


def test_analytic_model_flops_train_vs_decode():
    from repro.configs import get_arch
    cfg = get_arch("tinyllama-1.1b")
    train = analytic_model_flops(cfg, "train_4k")
    dec = analytic_model_flops(cfg, "decode_32k")
    assert train > dec * 1000
    # train = 6 * N * D
    from repro.configs.base import param_count
    _, active = param_count(cfg)
    assert abs(train - 6 * active * 256 * 4096) / train < 1e-9


# --------------------------------------------------------------------------
# FoG RooflineModel (the module that now lives at launch/roofline.py)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_pack(request):
    """A tiny packed field: 1 head, 4 groves x 2 trees, depth 3, 4 classes."""
    from repro.forest.pack import ForestPack
    from repro.core.grove import split
    from repro.forest.train import TrainConfig, train_random_forest
    rng = np.random.default_rng(0)
    X = rng.standard_normal((300, 12)).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.int32) + 2 * (X[:, 1] > 0).astype(np.int32)
    rf = train_random_forest(X, y, 4, TrainConfig(n_trees=8, max_depth=3,
                                                  seed=0))
    return ForestPack.from_groves(split(rf, 2), "fp32")


def test_roofline_fused_moves_fewer_table_bytes(small_pack):
    """The paper's claim, in model form: the fused pin touches the tables
    once while the per-hop loop re-gathers a grove slice per lane per
    iteration — at any realistic batch the per-hop traffic dominates."""
    m = RooflineModel(small_pack, n_features=12)
    per_hop = m.estimate("reference", batch=1000, iters=4)
    fused = m.estimate("fused", batch=1000, iters=4, hops_total=1300.0)
    assert fused.bytes_moved < per_hop.bytes_moved
    assert per_hop.bytes_moved >= 1000 * 4 * (small_pack.table_bytes
                                              / small_pack.n_groves)


def test_roofline_dtype_aware_bytes(small_pack):
    """int8 tables move a quarter of the fp32 per-hop table traffic."""
    p8 = small_pack.astype("int8")
    f32 = RooflineModel(small_pack, 12).estimate("reference", 100, iters=4)
    i8 = RooflineModel(p8, 12).estimate("reference", 100, iters=4)
    assert i8.bytes_moved < f32.bytes_moved
    # table term shrinks ~4x; the fp32 row/state terms are shared
    assert p8.table_bytes < small_pack.table_bytes / 2


def test_roofline_bound_and_achieved(small_pack):
    m = RooflineModel(small_pack, 12, spec=TPU_V5E)
    est = m.estimate("reference", 1000, iters=4)
    assert est.bound in ("memory", "compute")
    assert est.ideal_s == max(est.memory_s, est.compute_s) > 0
    # achieved: ideal/measured, clamped-safe on zero/missing measurements
    assert est.achieved(2 * est.ideal_s) == pytest.approx(0.5)
    assert est.achieved(0.0) == 0.0
    assert est.achieved(None) == 0.0
    d = est.to_dict(measured_s=est.ideal_s)
    assert d["bound"] == est.bound
    assert d["achieved_pct"] == pytest.approx(100.0, abs=0.01)


def test_roofline_spec_selection(small_pack):
    """Specs are configurable by name or value; slower machines lower the
    roofline (bigger ideal_s)."""
    by_name = RooflineModel(small_pack, 12, spec="host-cpu")
    assert by_name.spec is HOST_CPU
    custom = MachineSpec("slow", peak_flops=1e9, peak_bw=1e9)
    slow = RooflineModel(small_pack, 12, spec=custom).estimate(
        "fused", 100, iters=4)
    fast = RooflineModel(small_pack, 12, spec=TPU_V5E).estimate(
        "fused", 100, iters=4)
    assert slow.ideal_s > fast.ideal_s
    assert slow.bytes_moved == fast.bytes_moved   # traffic is machine-free


def test_roofline_compaction_cuts_compute_not_bytes(small_pack):
    """Compaction scales the fused compute term with Σ hops; HBM traffic
    is unchanged (state lives in VMEM either way)."""
    m = RooflineModel(small_pack, 12)
    off = m.estimate("fused", 1000, iters=4, hops_total=1300.0,
                     compact=False)
    on = m.estimate("fused", 1000, iters=4, hops_total=1300.0, compact=True)
    assert on.flops < off.flops
    assert on.bytes_moved == off.bytes_moved


def test_roofline_row_names_map_to_traffic_class(small_pack):
    """Benchmark rows pass their OWN names ("pallas", "fused-compact",
    "reference-lazy"): the traffic class comes from the name root, the
    estimate reports the full name — so BENCH_engine.json roofline rows
    are labeled by the backend that was actually measured."""
    m = RooflineModel(small_pack, 12)
    ref = m.estimate("reference", 1000, iters=4)
    for name in ("pallas", "pallas-chunked", "reference-lazy"):
        est = m.estimate(name, 1000, iters=4)
        assert est.backend == name
        assert est.bytes_moved == ref.bytes_moved    # per-hop traffic
        assert est.flops == ref.flops
    fused = m.estimate("fused", 1000, iters=4, hops_total=1300.0,
                       compact=True)
    named = m.estimate("fused-compact", 1000, iters=4, hops_total=1300.0,
                       compact=True)
    assert named.backend == "fused-compact"
    assert named.bytes_moved == fused.bytes_moved    # one table pin
    assert named.flops == fused.flops
