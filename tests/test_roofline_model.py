"""HLO cost model unit tests against hand-crafted HLO text."""
import numpy as np

from repro.launch.roofline import (
    HloCostModel, _shape_bytes, analytic_model_flops, collective_bytes_from_hlo,
)

HLO = """\
HloModule test

%fused_inner (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  ROOT %e = f32[128,64]{1,0} exponential(%p0)
}

%body (arg: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %arg = (s32[], f32[128,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[128,64]{1,0} get-tuple-element(%arg), index=1
  %w = f32[64,64]{1,0} constant({...})
  %d = f32[128,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %f = f32[128,64]{1,0} fusion(%d), kind=kLoop, calls=%fused_inner
  %ar = f32[128,64]{1,0} all-reduce(%f), replica_groups={}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,64]{1,0}) tuple(%ip, %ar)
}

%cond (arg: (s32[], f32[128,64])) -> pred[] {
  %arg = (s32[], f32[128,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,64]) -> f32[128,64] {
  %x = f32[128,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,64]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[128,64]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1


def test_cost_model_scales_loop_body():
    m = HloCostModel(HLO)
    assert m.entry == "main"
    t = m.totals()
    # dot: 2 * 128*64 * 64 contracted = 1,048,576 flops x 10 trips
    assert t["flops"] == 10 * 2 * 128 * 64 * 64
    # collective: all-reduce result 32 KiB x 10 trips
    assert t["collective_bytes"] == 10 * 128 * 64 * 4
    assert t["collective_by_kind"] == {"all-reduce": 10 * 128 * 64 * 4}


def test_collective_regex_fallback():
    got = collective_bytes_from_hlo(HLO)
    assert got["all-reduce"] == 128 * 64 * 4   # unscaled single-pass parse


def test_analytic_model_flops_train_vs_decode():
    from repro.configs import get_arch
    cfg = get_arch("tinyllama-1.1b")
    train = analytic_model_flops(cfg, "train_4k")
    dec = analytic_model_flops(cfg, "decode_32k")
    assert train > dec * 1000
    # train = 6 * N * D
    from repro.configs.base import param_count
    _, active = param_count(cfg)
    assert abs(train - 6 * active * 256 * 4096) / train < 1e-9
