"""Pareto frontier + auto_policy (core/frontier.py) — the planning layer."""
import jax
import numpy as np
import pytest

from repro.core import (FogEngine, FogPolicy, Frontier, FrontierPoint,
                        auto_policy, build_frontier, default_grid,
                        sweep_policies, split)
from repro.core.frontier import find_opt_threshold, select_min_edp


def _pt(thresh, acc, nj, hops=1.0):
    return FrontierPoint(policy=FogPolicy(threshold=thresh), accuracy=acc,
                         energy_nj=nj, mean_hops=hops)


# ---------------------------------------------------------------- pruning ----
def test_dominated_points_are_pruned():
    pts = [_pt(0.1, 0.90, 1.0), _pt(0.2, 0.95, 2.0),
           _pt(0.3, 0.93, 3.0),      # dominated: pricier AND less accurate
           _pt(0.4, 0.97, 4.0),
           _pt(0.5, 0.95, 2.5)]      # dominated by the 0.95 @ 2.0 point
    f = Frontier(pts)
    assert [p.accuracy for p in f.points] == [0.90, 0.95, 0.97]
    f.check_monotone()


def test_duplicate_accuracy_keeps_cheapest():
    f = Frontier([_pt(0.1, 0.95, 1.0), _pt(0.2, 0.95, 2.0)])
    assert len(f) == 1 and f.points[0].energy_nj == 1.0


def test_check_monotone_rejects_violation():
    f = Frontier([_pt(0.1, 0.9, 1.0), _pt(0.2, 0.95, 2.0)])
    # sabotage the invariant the way a regressed builder would
    object.__setattr__(f.points[1], "accuracy", 0.5)
    with pytest.raises(AssertionError, match="not monotone"):
        f.check_monotone()


def test_empty_frontier_rejected():
    with pytest.raises(ValueError):
        Frontier([])


# ----------------------------------------------------------- under budget ----
def test_under_budget_picks_highest_accuracy_fitting():
    f = Frontier([_pt(0.1, 0.90, 1.0), _pt(0.2, 0.95, 2.0),
                  _pt(0.4, 0.97, 4.0)])
    assert f.under_budget(2.5).accuracy == 0.95
    assert f.under_budget(100.0).accuracy == 0.97
    with pytest.raises(ValueError, match="below the cheapest"):
        f.under_budget(0.5)


def test_ladder_is_quality_descending():
    f = Frontier([_pt(0.1, 0.90, 1.0), _pt(0.2, 0.95, 2.0)])
    ladder = f.ladder()
    assert [p.accuracy for p in ladder] == [0.95, 0.90]


# ----------------------------------------------------------- persistence ----
def test_frontier_round_trips_through_dict():
    f = Frontier([_pt(0.1, 0.90, 1.0),
                  FrontierPoint(FogPolicy(threshold=0.3, precision="int8",
                                          hop_budget=3), 0.95, 2.0, 1.5)])
    f2 = Frontier.from_dict(f.to_dict())
    assert len(f2) == len(f)
    for a, b in zip(f.points, f2.points):
        assert a.policy == b.policy
        assert (a.accuracy, a.energy_nj, a.mean_hops) == \
            (b.accuracy, b.energy_nj, b.mean_hops)


def test_from_dict_is_verbatim_so_the_energy_gate_can_fail():
    """CI's energy_gate loads the dumped frontier and runs check_monotone:
    from_dict must NOT re-sort/re-prune, or a regressed builder's
    non-monotone dump would be silently repaired and the gate could never
    fail."""
    bad = {"points": [_pt(0.1, 0.95, 1.0).to_dict(),
                      _pt(0.2, 0.90, 2.0).to_dict()]}   # acc drops: bogus
    f = Frontier.from_dict(bad)
    assert len(f) == 2                       # nothing silently dropped
    with pytest.raises(AssertionError, match="not monotone"):
        f.check_monotone()
    # but an energy-UNSORTED dump fails at load: under_budget's last-
    # fitting-point scan depends on the stored order
    unsorted = {"points": [_pt(0.2, 0.95, 2.0).to_dict(),
                           _pt(0.1, 0.90, 1.0).to_dict()]}
    with pytest.raises(ValueError, match="energy-sorted"):
        Frontier.from_dict(unsorted)


def test_per_lane_policy_refuses_to_serialize():
    import jax.numpy as jnp
    p = FogPolicy(threshold=jnp.asarray([0.1, 0.2]))
    with pytest.raises(ValueError, match="per-lane"):
        p.to_dict()


# ------------------------------------------------------ generic selectors ----
def test_selectors_work_on_frontier_points():
    pts = [_pt(0.1, 0.90, 1.0, 1.0), _pt(0.3, 0.95, 2.0, 2.0),
           _pt(0.7, 0.952, 4.0, 4.0)]
    assert select_min_edp(pts, accuracy_slack=0.02).accuracy == 0.95
    assert find_opt_threshold(pts, tolerance=0.005).accuracy == 0.95


# ------------------------------------------------------------ the real API ----
@pytest.fixture(scope="module")
def quickstart(ds_penbased):
    """The README quickstart forest: 16 trees, depth 8, 8x2 groves."""
    from repro.forest import TrainConfig, train_random_forest
    ds = ds_penbased
    rf = train_random_forest(ds.x_train, ds.y_train, ds.n_classes,
                             TrainConfig(n_trees=16, max_depth=8, seed=0))
    return ds, FogEngine(split(rf, 2))


def test_default_grid_covers_knob_plane():
    grid = default_grid(thresholds=(0.1, 0.3), hop_budgets=(None, 2),
                        precisions=(None, "int8"))
    assert len(grid) == 8
    assert {p.precision for p in grid} == {None, "int8"}
    assert {p.hop_budget for p in grid} == {None, 2}


def test_sweep_prices_with_engine_telemetry(quickstart):
    ds, engine = quickstart
    pts = sweep_policies(engine, ds.x_test[:256], ds.y_test[:256],
                         [FogPolicy(threshold=0.1), FogPolicy(threshold=0.9)])
    assert pts[0].energy_nj < pts[1].energy_nj      # tighter = cheaper
    assert all(p.energy_nj > 0 and 0 < p.accuracy <= 1 for p in pts)
    assert "nJ" in str(pts[0])                      # nJ units in sweep logs


def test_auto_policy_meets_2nj_budget_within_2pct_accuracy(quickstart):
    """The PR's acceptance criterion: on the quickstart forest, auto_policy
    under a 2 nJ/classification budget stays within 2% of the unconstrained
    fp32 default policy's accuracy — and actually fits the budget when
    re-evaluated."""
    ds, engine = quickstart
    x_cal, y_cal = ds.x_test[:512], ds.y_test[:512]
    budget_nj = 2.0
    pol = auto_policy(engine, x_cal, y_cal, energy_budget_nj=budget_nj)
    import jax.numpy as jnp
    key = jax.random.key(0)
    unconstrained = engine.eval(jnp.asarray(x_cal), key,
                                policy=FogPolicy(threshold=0.3))
    chosen = engine.eval(jnp.asarray(x_cal), key, policy=pol)
    acc_unc = float((np.asarray(unconstrained.label) == y_cal).mean())
    acc = float((np.asarray(chosen.label) == y_cal).mean())
    assert acc >= acc_unc - 0.02
    assert chosen.energy_report().per_example_nj <= budget_nj
    assert float(np.asarray(chosen.energy_pj).mean()) * 1e-3 <= budget_nj


def test_sweep_dedupes_policies_that_resolve_identically(quickstart):
    """On an int8-default engine, precision=None grid points resolve to
    the explicit int8 axis — the sweep must not pay two calibration evals
    for one effective policy, and stored points carry the RESOLVED
    precision (never None) so artifacts stay faithful."""
    ds, engine = quickstart
    int8_engine = FogEngine(engine.gcs[0], precision="int8")
    pts = sweep_policies(int8_engine, ds.x_test[:128], ds.y_test[:128],
                         default_grid(thresholds=(0.1, 0.3)))
    assert len(pts) == 2                      # not 4: (None,int8) collapsed
    assert all(p.policy.precision == "int8" for p in pts)


def test_save_keeps_highest_fidelity_frontier_precision(quickstart, tmp_path):
    """An artifact carrying a mixed-precision frontier must persist the
    pack at the highest-fidelity rung precision: an int8 pack could not
    faithfully serve an fp32 rung after load."""
    from repro.sklearn import FogClassifier
    ds, _ = quickstart
    clf = FogClassifier(n_trees=16, grove_size=2, max_depth=6, seed=1)
    clf.fit(ds.x_train, ds.y_train)
    clf.set_energy_budget(
        2.0, ds.x_test[:128], ds.y_test[:128],
        policies=[FogPolicy(threshold=0.3),
                  FogPolicy(threshold=0.3, precision="int8"),
                  FogPolicy(threshold=0.1, precision="int8")])
    precs = {p.policy.precision for p in clf.frontier_.points}
    path = clf.save(tmp_path / "mixed.npz")
    from repro.forest.pack import ForestPack
    pack, _ = ForestPack.load_with_meta(path)
    assert pack.precision == ("fp32" if "fp32" in precs else "int8")


def test_frontier_monotone_on_real_forest(quickstart):
    ds, engine = quickstart
    f = build_frontier(engine, ds.x_test[:512], ds.y_test[:512])
    f.check_monotone()
    assert len(f) >= 3
