"""End-to-end training integration: loss decreases, checkpoints resume
bit-exact, gradient compression still converges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.lm_data import DataConfig, batch_at_step
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.loop import make_train_step
from repro import compat


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_arch("tinyllama-1.1b").scaled(
        name="tiny-test", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256)


def _run(cfg, steps, *, compress=False, params=None, opt_state=None,
         start=0, seed=0):
    mesh = make_host_mesh()
    with compat.set_mesh(mesh):
        step_fn, *_, init_opt = make_train_step(
            cfg, mesh, lr=5e-3, total_steps=steps, donate=False,
            compress_pod_grads=compress)
        if params is None:
            params = T.init_params(cfg, jax.random.key(seed), jnp.float32)
            opt_state = init_opt(params)
        dcfg = DataConfig(cfg.vocab_size, 64, 4, seed=seed)
        losses = []
        for s in range(start, steps):
            b = batch_at_step(dcfg, s)
            params, opt_state, m = step_fn(
                params, opt_state,
                {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])})
            losses.append(float(m["loss"]))
        return params, opt_state, losses


def test_loss_decreases(tiny_cfg):
    _, _, losses = _run(tiny_cfg, 30)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_compressed_grads_still_converge(tiny_cfg):
    _, _, losses = _run(tiny_cfg, 30, compress=True)
    assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])


def test_checkpoint_resume_bit_exact(tiny_cfg, tmp_path):
    """Crash at step 10, resume: steps 10..20 must equal the uninterrupted
    run (deterministic data + saved optimizer state)."""
    p1, o1, l_full = _run(tiny_cfg, 20)

    p2, o2, _ = _run(tiny_cfg, 10)
    ckpt.save(10, (p2, o2), tmp_path)
    (p3, o3), step = ckpt.restore((p2, o2), tmp_path)
    assert step == 10
    p4, o4, l_resumed = _run(tiny_cfg, 20, params=p3, opt_state=o3, start=10)

    np.testing.assert_allclose(l_resumed, l_full[10:], rtol=1e-5, atol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), p1, p4)
